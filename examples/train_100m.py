"""Train a ~100M-parameter qwen3-family model for a few hundred steps on
CPU, with checkpointing + preemption + restart-safe resume (deliverable b:
the end-to-end training driver).

Runs the SAME code path as the production launcher (launch/train.py) —
this wrapper just picks CPU-sized knobs and simulates one preemption.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""
import argparse
import shutil
import tempfile

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="mcsa_100m_")
    common = ["--arch", "qwen3-8b", "--size", "100m",
              "--steps", str(args.steps), "--seq", str(args.seq),
              "--batch", str(args.batch), "--ckpt-dir", ckpt_dir,
              "--ckpt-every", "25", "--log-every", "10"]
    try:
        print("== phase 1: train until 'preemption' at half way ==")
        train_driver.main(common + ["--stop-after",
                                    str(args.steps // 2), "--resume"])
        print("\n== phase 2: restart, resume from checkpoint, finish ==")
        train_driver.main(common + ["--resume"])
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
