"""Mobility simulation: a fleet of users streaming inference requests
while driving through the AP grid — live MLi-GD decisions + running
per-strategy cost accounting (the paper's Figs. 9-14 scenario, animated
as text).

Run:  PYTHONPATH=src python examples/mobility_sim.py [--minutes 30]
"""
import argparse

import numpy as np

from repro.configs.chain_cnns import yolov2
from repro.core.costs import DeviceParams
from repro.core.ligd import LiGDConfig
from repro.core.mobility import RandomWaypointMobility
from repro.core.network import build_topology
from repro.core.planner import MCSAPlanner
from repro.core.profile import profile_of


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=int, default=30)
    ap.add_argument("--users", type=int, default=10)
    args = ap.parse_args()

    topo = build_topology(25, 3, seed=0)
    profile = profile_of(yolov2())
    planner = MCSAPlanner(profile, topo, LiGDConfig(max_iters=250))
    rng = np.random.default_rng(0)
    devices = [DeviceParams(c_dev=float(rng.uniform(3e9, 6e9)))
               for _ in range(args.users)]
    mob = RandomWaypointMobility(topo, args.users, seed=1,
                                 speed_range=(8.0, 25.0))   # vehicles

    aps = topo.nearest_ap(mob.positions())
    _, _, plans = planner.plan_static(devices, aps)
    print(f"{args.users} vehicles, {topo.num_aps} APs, "
          f"{topo.num_servers} edge servers; YOLOv2 inference stream")

    resplits = relays = 0
    lat_log = []
    for minute in range(args.minutes):
        events = mob.step(60.0, minute * 60.0)
        if events:
            planner.on_handoffs(events, devices, plans)
            for ev in events:
                p = plans[ev.user]
                if p.R:
                    relays += 1
                else:
                    resplits += 1
                print(f"  [{minute:3d} min] vehicle {ev.user}: server "
                      f"{ev.old_server}->{ev.new_server} "
                      f"{'relay-back' if p.R else 're-split'} "
                      f"(split={p.split}, T={p.T * 1e3:.1f} ms)")
        lat_log.append(np.mean([p.T for p in plans]))

    print(f"\n{args.minutes} min simulated: {resplits} re-splits, "
          f"{relays} relay-backs")
    print(f"fleet mean latency: {np.mean(lat_log) * 1e3:.1f} ms "
          f"(worst minute {np.max(lat_log) * 1e3:.1f} ms)")


if __name__ == "__main__":
    main()
