"""Mobility simulation: a fleet of users streaming inference requests
while driving through the AP grid — live MLi-GD decisions + running
per-strategy cost accounting (the paper's Figs. 9-14 scenario, animated
as text).

The whole loop is array-resident: mobility steps, handoff batches, and
plan updates are vectorized end-to-end, so ``--users 100000`` is a flag
away (each minute costs one padded MLi-GD solve over that minute's
handoffs, not a Python loop over vehicles).

Control-plane extras (docs/ARCHITECTURE.md):
  --candidates K        admit each vehicle to the best of its K nearest
                        servers (water-filling under budgets)
  --server-capacity R   per-server compute budget (units) — forces
                        spills/rejections when tight
  --async-replanning    overlap each minute's MLi-GD solve with the next
                        mobility step (decisions land one minute late)

Run:  PYTHONPATH=src python examples/mobility_sim.py [--minutes 30]
      PYTHONPATH=src python examples/mobility_sim.py --users 100000
      PYTHONPATH=src python examples/mobility_sim.py \\
          --candidates 3 --server-capacity 200 --async-replanning
"""
import argparse

import numpy as np

from repro.configs.chain_cnns import yolov2
from repro.core.costs import DeviceFleet
from repro.core.ligd import LiGDConfig
from repro.core.mobility import RandomWaypointMobility
from repro.core.network import build_topology
from repro.core.planner import MCSAPlanner
from repro.core.profile import profile_of

MAX_EVENT_PRINTS = 8


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=int, default=30)
    ap.add_argument("--users", type=int, default=10)
    ap.add_argument("--candidates", type=int, default=1,
                    help="candidate servers per vehicle (K)")
    ap.add_argument("--server-capacity", type=float, default=None,
                    help="per-server compute budget in units "
                         "(default: uncapacitated)")
    ap.add_argument("--async-replanning", action="store_true",
                    help="overlap handoff solves with the next step")
    args = ap.parse_args()

    topo = build_topology(25, 3, seed=0, r_capacity=args.server_capacity)
    profile = profile_of(yolov2())
    planner = MCSAPlanner(profile, topo, LiGDConfig(max_iters=250),
                          candidates_k=args.candidates,
                          async_replanning=args.async_replanning)
    rng = np.random.default_rng(0)
    devices = DeviceFleet(c_dev=rng.uniform(3e9, 6e9, args.users))
    mob = RandomWaypointMobility(topo, args.users, seed=1,
                                 speed_range=(8.0, 25.0))   # vehicles

    aps = topo.nearest_ap(mob.positions())
    _, _, fleet = planner.plan_static(devices, aps)
    print(f"{args.users} vehicles, {topo.num_aps} APs, "
          f"{topo.num_servers} edge servers; YOLOv2 inference stream")
    rep = planner.last_admission
    if rep is not None:
        spilled = int(((rep.spills > 0) & ~rep.rejected).sum())
        print(f"admission: K={args.candidates}, "
              f"users/server {rep.users_per_server.tolist()}, "
              f"{spilled} spilled, {int(rep.rejected.sum())} device-only"
              + (f", r-load {np.round(rep.r_load, 1).tolist()}"
                 f" / budget {args.server_capacity}"
                 if args.server_capacity else ""))

    resplits = relays = 0
    lat_log = []
    for minute in range(args.minutes):
        events = mob.step(60.0, minute * 60.0)
        if events:
            res = planner.on_handoffs(events, devices, fleet)
            if args.async_replanning:
                # forcing res here would kill the overlap — the decisions
                # land at the next minute's call (or the final drain)
                print(f"  [{minute:3d} min] {len(events)} handoffs "
                      f"(solve in flight)")
                lat_log.append(fleet.T.mean())
                continue
            R = np.asarray(res.R)
            relays += int(R.sum())
            resplits += int(len(R) - R.sum())
            for i, ev in enumerate(events):
                if i >= MAX_EVENT_PRINTS:
                    print(f"  [{minute:3d} min] ... "
                          f"{len(events) - MAX_EVENT_PRINTS} more handoffs")
                    break
                print(f"  [{minute:3d} min] vehicle {ev.user}: server "
                      f"{ev.old_server}->{ev.new_server} "
                      f"{'relay-back' if R[i] else 're-split'} "
                      f"(split={int(fleet.split[ev.user])}, "
                      f"T={fleet.T[ev.user] * 1e3:.1f} ms)")
        lat_log.append(fleet.T.mean())

    planner.drain(fleet)
    if args.async_replanning:
        relays = int((fleet.R == 1).sum())
        print(f"\n{args.minutes} min simulated (async): "
              f"{relays} vehicles ended on a relay-back plan")
    else:
        print(f"\n{args.minutes} min simulated: {resplits} re-splits, "
              f"{relays} relay-backs")
    print(f"fleet mean latency: {np.mean(lat_log) * 1e3:.1f} ms "
          f"(worst minute {np.max(lat_log) * 1e3:.1f} ms)")


if __name__ == "__main__":
    main()
