"""Mobility simulation: a fleet of users streaming inference requests
while driving through the AP grid — live MLi-GD decisions + running
per-strategy cost accounting (the paper's Figs. 9-14 scenario, animated
as text).

Everything rides the ``repro.api`` surface: the world is the
``paper_fig1`` Scenario preset (CLI flags override its fields) and the
whole mobility → handoff → replan loop is owned by ``Session`` — this
file only prints what each step reports.  The loop is array-resident
end-to-end, so ``--users 100000`` is a flag away (each minute costs one
padded MLi-GD solve over that minute's handoffs, not a Python loop over
vehicles).

Control-plane extras (docs/ARCHITECTURE.md):
  --candidates K        admit each vehicle to the best of its K nearest
                        servers (water-filling under budgets)
  --server-capacity R   per-server compute budget (units) — forces
                        spills/rejections when tight
  --async-replanning    overlap each minute's MLi-GD solve with the next
                        mobility step (decisions land one minute late)

Run:  PYTHONPATH=src python examples/mobility_sim.py [--minutes 30]
      PYTHONPATH=src python examples/mobility_sim.py --users 100000
      PYTHONPATH=src python examples/mobility_sim.py \\
          --candidates 3 --server-capacity 200 --async-replanning
"""
import argparse

import numpy as np

from repro.api import Session, get_scenario

MAX_EVENT_PRINTS = 8


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=int, default=30)
    ap.add_argument("--users", type=int, default=10)
    ap.add_argument("--candidates", type=int, default=1,
                    help="candidate servers per vehicle (K)")
    ap.add_argument("--server-capacity", type=float, default=None,
                    help="per-server compute budget in units "
                         "(default: uncapacitated)")
    ap.add_argument("--async-replanning", action="store_true",
                    help="overlap handoff solves with the next step")
    args = ap.parse_args()

    scenario = get_scenario("paper_fig1").replace(
        steps=args.minutes, num_users=args.users,
        candidates_k=args.candidates, r_capacity=args.server_capacity,
        async_replanning=args.async_replanning)
    sess = Session(scenario)
    print(f"{args.users} vehicles, {sess.topo.num_aps} APs, "
          f"{sess.topo.num_servers} edge servers; YOLOv2 inference stream")
    if sess.admission is not None:
        rep = sess.admission
        print(f"admission: K={args.candidates}, "
              f"users/server {rep['users_per_server']}, "
              f"{rep['spilled']} spilled, {rep['rejected']} device-only"
              + (f", r-load {np.round(rep['r_load'], 1).tolist()}"
                 f" / budget {args.server_capacity}"
                 if args.server_capacity else ""))

    fleet = sess.fleet
    for minute in range(args.minutes):
        rep = sess.step()
        if rep.in_flight:
            # the solve overlaps the next minute's mobility — decisions
            # land at the next event-bearing step (or the final drain)
            if rep.events:
                print(f"  [{minute:3d} min] {len(rep.events)} handoffs "
                      f"(solve in flight)")
            continue
        if rep.result is None:
            continue
        R = np.asarray(rep.result.R)
        for i, ev in enumerate(rep.events):
            if i >= MAX_EVENT_PRINTS:
                print(f"  [{minute:3d} min] ... "
                      f"{len(rep.events) - MAX_EVENT_PRINTS} more handoffs")
                break
            print(f"  [{minute:3d} min] vehicle {ev.user}: server "
                  f"{ev.old_server}->{ev.new_server} "
                  f"{'relay-back' if R[i] else 're-split'} "
                  f"(split={int(fleet.split[ev.user])}, "
                  f"T={fleet.T[ev.user] * 1e3:.1f} ms)")

    sess.drain()
    m = sess.metrics()
    if args.async_replanning:
        relays = int((fleet.R == 1).sum())
        print(f"\n{args.minutes} min simulated (async): "
              f"{relays} vehicles ended on a relay-back plan")
    else:
        print(f"\n{args.minutes} min simulated: "
              f"{int(m.resplits.sum())} re-splits, "
              f"{int(m.relays.sum())} relay-backs")
    print(f"fleet mean latency: {np.mean(m.mean_T) * 1e3:.1f} ms "
          f"(worst minute {np.max(m.mean_T) * 1e3:.1f} ms)")


if __name__ == "__main__":
    main()
