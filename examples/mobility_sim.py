"""Mobility simulation: a fleet of users streaming inference requests
while driving through the AP grid — live MLi-GD decisions + running
per-strategy cost accounting (the paper's Figs. 9-14 scenario, animated
as text).

The whole loop is array-resident: mobility steps, handoff batches, and
plan updates are vectorized end-to-end, so ``--users 100000`` is a flag
away (each minute costs one padded MLi-GD solve over that minute's
handoffs, not a Python loop over vehicles).

Run:  PYTHONPATH=src python examples/mobility_sim.py [--minutes 30]
      PYTHONPATH=src python examples/mobility_sim.py --users 100000
"""
import argparse

import numpy as np

from repro.configs.chain_cnns import yolov2
from repro.core.costs import DeviceFleet
from repro.core.ligd import LiGDConfig
from repro.core.mobility import RandomWaypointMobility
from repro.core.network import build_topology
from repro.core.planner import MCSAPlanner
from repro.core.profile import profile_of

MAX_EVENT_PRINTS = 8


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=int, default=30)
    ap.add_argument("--users", type=int, default=10)
    args = ap.parse_args()

    topo = build_topology(25, 3, seed=0)
    profile = profile_of(yolov2())
    planner = MCSAPlanner(profile, topo, LiGDConfig(max_iters=250))
    rng = np.random.default_rng(0)
    devices = DeviceFleet(c_dev=rng.uniform(3e9, 6e9, args.users))
    mob = RandomWaypointMobility(topo, args.users, seed=1,
                                 speed_range=(8.0, 25.0))   # vehicles

    aps = topo.nearest_ap(mob.positions())
    _, _, fleet = planner.plan_static(devices, aps)
    print(f"{args.users} vehicles, {topo.num_aps} APs, "
          f"{topo.num_servers} edge servers; YOLOv2 inference stream")

    resplits = relays = 0
    lat_log = []
    for minute in range(args.minutes):
        events = mob.step(60.0, minute * 60.0)
        if events:
            res = planner.on_handoffs(events, devices, fleet)
            R = np.asarray(res.R)
            relays += int(R.sum())
            resplits += int(len(R) - R.sum())
            for i, ev in enumerate(events):
                if i >= MAX_EVENT_PRINTS:
                    print(f"  [{minute:3d} min] ... "
                          f"{len(events) - MAX_EVENT_PRINTS} more handoffs")
                    break
                print(f"  [{minute:3d} min] vehicle {ev.user}: server "
                      f"{ev.old_server}->{ev.new_server} "
                      f"{'relay-back' if R[i] else 're-split'} "
                      f"(split={int(fleet.split[ev.user])}, "
                      f"T={fleet.T[ev.user] * 1e3:.1f} ms)")
        lat_log.append(fleet.T.mean())

    print(f"\n{args.minutes} min simulated: {resplits} re-splits, "
          f"{relays} relay-backs")
    print(f"fleet mean latency: {np.mean(lat_log) * 1e3:.1f} ms "
          f"(worst minute {np.max(lat_log) * 1e3:.1f} ms)")


if __name__ == "__main__":
    main()
