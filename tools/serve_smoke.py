#!/usr/bin/env python
"""CI serve smoke: run a ``serve_*`` preset's closed loop end-to-end and
assert the data-plane invariants hold (docs/ARCHITECTURE.md, "Serving
data plane"):

* ZERO lost requests — ``submitted == done + device + degraded`` even
  under the scripted mid-decode server kill (``drain`` raises on its
  own, but we re-check the summary arithmetic here);
* the kill actually interrupted live decode streams: at least one
  mid-stream failover event was recorded and surfaced into
  ``metrics().faults["serving_failovers"]``;
* under ``failover_mode="auto"`` at least one failover chose KV-cache
  **migration** (the preset's virtual token time makes recompute far
  pricier than shipping the cache, so auto must pick migrate);
* shed requests were degraded to device-only, never dropped
  (``shed <= degraded``);
* real tokens were emitted by the pools that stayed up.

``--adaptive`` switches to the telemetry feedback smoke instead
(docs/ARCHITECTURE.md, "Telemetry & feedback"): run the hotspot preset
twice on the same seed — closed loop (``feedback=True``, the preset's
own setting) vs open loop (``feedback=False``) — and assert the
adaptive run strictly degrades fewer requests AND ends with a lower
p99 virtual token latency.

Run:  PYTHONPATH=src python tools/serve_smoke.py [--scenario NAME]
      PYTHONPATH=src python tools/serve_smoke.py --adaptive
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.api import Session, get_scenario


def _run_summary(sc):
    sess = Session(sc)
    for _ in range(sc.steps):
        sess.step()
    m = sess.run(0)
    return m.serving, m.telemetry


def adaptive_main(scenario: str) -> int:
    sc = get_scenario(scenario)
    if sc.serving is None:
        raise SystemExit(f"scenario {sc.name!r} has no ServeConfig")
    runs = {}
    for fb in (False, True):
        s = sc.replace(serving=dataclasses.replace(sc.serving,
                                                   feedback=fb))
        sv, tel = _run_summary(s)
        runs[fb] = sv
        mults = (tel["compute_mult_max"] if tel else [])
        print(f"feedback={'on ' if fb else 'off'}  "
              f"degraded={sv['degraded']:4d}  shed={sv['shed']:4d}  "
              f"timeouts={sv['timeouts']:4d}  "
              f"p99_tok={sv['token_latency_p99_s']:.3f}s  "
              f"peak_mult={max(mults) if mults else 1.0:.2f}")
        assert sv["lost"] == 0, f"feedback={fb} lost requests"
    off, on = runs[False], runs[True]
    assert on["degraded"] < off["degraded"], \
        (f"closed loop must strictly degrade fewer requests: "
         f"on={on['degraded']} off={off['degraded']}")
    assert (on["token_latency_p99_s"] is not None
            and off["token_latency_p99_s"] is not None
            and on["token_latency_p99_s"] < off["token_latency_p99_s"]), \
        (f"closed loop must lower p99 token latency: "
         f"on={on['token_latency_p99_s']} "
         f"off={off['token_latency_p99_s']}")
    print(f"\nADAPTIVE_SMOKE_OK degraded {off['degraded']} -> "
          f"{on['degraded']}, p99 {off['token_latency_p99_s']:.3f}s -> "
          f"{on['token_latency_p99_s']:.3f}s")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="serve_chaos_k3",
                    help="a registered preset with a ServeConfig "
                         "(default: serve_chaos_k3)")
    ap.add_argument("--min-failovers", type=int, default=1,
                    help="required mid-stream failover events (0 for "
                         "fault-free presets)")
    ap.add_argument("--adaptive", nargs="?", const="serve_hotspot_k3",
                    default=None, metavar="NAME",
                    help="run the feedback on-vs-off comparison on NAME "
                         "(default: serve_hotspot_k3) instead of the "
                         "failover smoke")
    args = ap.parse_args(argv)
    if args.adaptive is not None:
        return adaptive_main(args.adaptive)

    sc = get_scenario(args.scenario)
    if sc.serving is None:
        raise SystemExit(f"scenario {sc.name!r} has no ServeConfig — "
                         f"nothing to smoke")
    if args.min_failovers > 0 and sc.faults is None:
        raise SystemExit(f"scenario {sc.name!r} has no FaultConfig but "
                         f"--min-failovers {args.min_failovers}")

    session = Session(sc)
    for i in range(sc.steps):
        rep = session.step()
        s = rep.serving
        print(f"step {i:2d}  t={rep.t:6.0f}s  "
              f"avail={session.topo.availability:4.2f}  "
              f"active={s['active']:4d}  queued={s['queued']:4d}  "
              f"done={s['completed']:5d}/{s['submitted']:5d}")
    m = session.run(0)          # drain raises if any request is lost
    s = m.serving

    assert s["lost"] == 0, f"data plane lost {s['lost']} request(s)"
    assert (s["submitted"] == s["completed"] + s["device"]
            + s["degraded"]), f"terminal-state arithmetic broken: {s}"
    assert s["shed"] <= s["degraded"], \
        f"shed {s['shed']} > degraded {s['degraded']} — sheds dropped?"
    assert s["tokens_emitted"] > 0, "no real decode tokens emitted"
    if args.min_failovers > 0:
        assert s["failover_events"] >= args.min_failovers, \
            (f"expected >= {args.min_failovers} mid-stream failover(s), "
             f"got {s['failover_events']}")
        fo = (m.faults or {}).get("serving_failovers")
        assert fo is not None and fo["events"] >= args.min_failovers, \
            f"failovers not surfaced into metrics().faults: {m.faults}"
        if sc.serving.failover_mode == "auto":
            assert s["failovers_migrate"] >= 1, \
                (f"auto mode never chose KV-cache migration: "
                 f"migrate={s['failovers_migrate']} "
                 f"reprefill={s['failovers_reprefill']}")
            assert fo["by_mode"]["migrate"] == s["failovers_migrate"], \
                f"by_mode split disagrees with summary: {fo['by_mode']}"

    print(f"\nSERVE_SMOKE_OK submitted={s['submitted']} "
          f"done={s['completed']} device={s['device']} "
          f"degraded={s['degraded']} lost=0 "
          f"failovers={s['failover_events']} "
          f"(migrate={s['failovers_migrate']} "
          f"reprefill={s['failovers_reprefill']}) "
          f"relay_ms={s['relay_s_total'] * 1e3:.2f} "
          f"peak_streams={s['peak_concurrent_streams']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
