#!/usr/bin/env python
"""CI chaos smoke: run a ``chaos_*`` preset end-to-end and assert the
fault-injection invariants hold (docs/ARCHITECTURE.md, "Failure
handling"):

* faults actually fired (availability dipped below 1.0);
* after EVERY step, zero users offload to a down server — affected
  users were evacuated to survivors or degraded to device-only within
  the step that killed their server;
* for purely-scripted scenarios whose schedule recovers everything it
  kills, availability is back to 1.0 at the end and every outage has a
  recorded time-to-recover.

Run:  PYTHONPATH=src python tools/chaos_smoke.py [--scenario NAME]
"""
from __future__ import annotations

import argparse

from repro.api import Session, get_scenario


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="chaos_singlefail_k3",
                    help="a registered chaos preset (default: "
                         "chaos_singlefail_k3)")
    ap.add_argument("--steps", type=int, default=None,
                    help="override the scenario's step count")
    args = ap.parse_args(argv)

    sc = get_scenario(args.scenario)
    if sc.faults is None:
        raise SystemExit(f"scenario {sc.name!r} has no FaultConfig — "
                         f"nothing to smoke")
    session = Session(sc)
    n = args.steps if args.steps is not None else sc.steps

    min_avail = 1.0
    for i in range(n):
        rep = session.step()
        avail = session.topo.availability
        min_avail = min(min_avail, avail)
        up = session.topo.server_available()
        offl = session.fleet.split < session.profile.num_layers
        stranded = int(((~up[session.fleet.server]) & offl).sum())
        evac = rep.evacuation
        print(f"step {i:2d}  t={rep.t:6.0f}s  avail={avail:4.2f}  "
              f"handoffs={len(rep.events):4d}  "
              f"evacuated={0 if evac is None else evac.evacuated:4d}  "
              f"degraded={0 if evac is None else evac.degraded:4d}  "
              f"stranded={stranded}")
        assert stranded == 0, \
            f"{stranded} users left offloading to a down server"
    session.drain()
    m = session.metrics()

    assert min_avail < 1.0, \
        f"{sc.name!r} injected no faults in {n} steps"
    assert m.faults is not None and m.faults["availability_min"] == \
        min_avail

    # a purely-scripted schedule that recovers everything it kills must
    # end fully available, with one time-to-recover sample per outage
    stochastic = (sc.faults.server_mtbf is not None
                  or sc.faults.link_mtbf is not None)
    downs = sum(ev[0] == "server_down" for ev in sc.faults.schedule)
    ups = sum(ev[0] == "server_up" for ev in sc.faults.schedule)
    if not stochastic and downs and downs == ups:
        assert session.topo.availability == 1.0, \
            "scripted recovery did not restore availability"
        assert len(m.faults["recovery_times_s"]) == downs
        assert not m.faults["still_down"]

    print("CHAOS_SMOKE_OK", {k: v for k, v in m.faults.items()
                             if k != "recovery_times_s"})
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
