#!/usr/bin/env python
"""CI policy-matrix smoke: run every registered policy against every
Scenario preset (optionally scale-capped) through the one
:class:`repro.api.Session` lifecycle and print the comparison table the
apples-to-apples design exists for.

Beyond "every cell runs", the matrix asserts the cross-cutting
invariants no single-policy test covers:

* every (scenario, policy) cell produces finite, positive fleet-mean
  delay — no NaN/inf escapes any solver or baseline path;
* chaos scenarios leave ZERO users offloading to a down server under
  EVERY policy — including baselines with no fault hook, which rely on
  Session's synthesized evacuation handoffs;
* per scenario, the MCSA planner's mean delay is never worse than the
  worst baseline (it optimizes utility, so delay alone need not win
  every cell — but losing to the whole field would mean the control
  plane is broken).

Run:  PYTHONPATH=src python tools/policy_matrix.py
      PYTHONPATH=src python tools/policy_matrix.py \\
          --max-users 64 --steps 4          # CI smoke scale
"""
from __future__ import annotations

import argparse
import json
import math
import time

from repro.api import (Session, get_scenario, list_policies,
                       list_scenarios)


def run_cell(scenario, policy: str) -> dict:
    """One (scenario, policy) cell: run the full schedule, return a
    summary row."""
    session = Session(scenario, policy=policy)
    t0 = time.perf_counter()
    m = session.run()
    wall = time.perf_counter() - t0

    offl = session.fleet.split < session.profile.num_layers
    stranded = 0
    if scenario.faults is not None:
        up = session.topo.server_available()
        stranded = int(((~up[session.fleet.server]) & offl).sum())

    return {
        "mean_T": float(m.mean_T.mean()),
        "final_T": float(m.mean_T[-1]),
        "mean_C": float(m.mean_C.mean()),
        "handoffs": int(m.handoffs.sum()),
        "evacuated": (int(m.evacuated.sum())
                      if m.evacuated is not None else 0),
        "offloading": int(offl.sum()),
        "stranded": stranded,
        "wall_s": wall,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated preset names "
                         "(default: every registered preset)")
    ap.add_argument("--policies", default=None,
                    help="comma-separated policy names "
                         "(default: every registered policy)")
    ap.add_argument("--max-users", type=int, default=None,
                    help="cap each scenario's fleet size (CI smoke)")
    ap.add_argument("--steps", type=int, default=None,
                    help="cap each scenario's step count (CI smoke)")
    ap.add_argument("--json", default=None,
                    help="also dump the matrix to this JSON path")
    args = ap.parse_args(argv)

    scenarios = (args.scenarios.split(",") if args.scenarios
                 else list(list_scenarios()))
    policies = (args.policies.split(",") if args.policies
                else list(list_policies()))

    matrix: dict[str, dict[str, dict]] = {}
    for sname in scenarios:
        sc = get_scenario(sname)
        # the matrix compares PLANNING policies; the serving data plane
        # is covered by its own smoke/bench (tools/serve_smoke.py)
        changes = {} if sc.serving is None else {"serving": None}
        if args.max_users is not None and sc.num_users > args.max_users:
            changes["num_users"] = args.max_users
        if args.steps is not None and sc.steps > args.steps:
            changes["steps"] = args.steps
        if changes:
            sc = sc.replace(**changes)
        matrix[sname] = {}
        for pname in policies:
            cell = run_cell(sc, pname)
            matrix[sname][pname] = cell
            assert math.isfinite(cell["mean_T"]) and cell["mean_T"] > 0, \
                f"{sname}/{pname}: non-finite mean delay {cell['mean_T']}"
            assert cell["stranded"] == 0, \
                (f"{sname}/{pname}: {cell['stranded']} users left "
                 f"offloading to a down server")

        if "mcsa" in matrix[sname] and len(matrix[sname]) > 1:
            worst = max(c["mean_T"] for p, c in matrix[sname].items()
                        if p != "mcsa")
            assert matrix[sname]["mcsa"]["mean_T"] <= worst * (1 + 1e-6), \
                (f"{sname}: MCSA mean delay "
                 f"{matrix[sname]['mcsa']['mean_T']:.4f}s is worse than "
                 f"every baseline (worst {worst:.4f}s)")

    # -- render ---------------------------------------------------------
    width = max(len(p) for p in policies) + 2
    head = "mean_T (s)".ljust(22) + "".join(p.rjust(width)
                                            for p in policies)
    print(head)
    print("-" * len(head))
    for sname in scenarios:
        row = sname.ljust(22)
        for pname in policies:
            row += f"{matrix[sname][pname]['mean_T']:.4f}".rjust(width)
        print(row)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(matrix, f, indent=2, sort_keys=True)
        print(f"\nwrote {args.json}")

    print("\nPOLICY_MATRIX_OK "
          f"({len(scenarios)} scenarios x {len(policies)} policies)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
