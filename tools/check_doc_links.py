#!/usr/bin/env python
"""Fail CI on broken intra-repo documentation links.

Scans every tracked ``*.md`` file for markdown links/images and verifies
that relative targets exist on disk AND that ``#anchor`` fragments match
a real heading in the target file (GitHub-style slugs; in-page ``#...``
links are checked against the file they appear in).  Also verifies the
``docs/...`` path references (an optional ``#anchor`` suffix is checked
too) that module docstrings use as cross-links.  External ``http(s):``/``mailto:``
targets are skipped.

Run:  python tools/check_doc_links.py  (from the repo root or anywhere)
"""
from __future__ import annotations

import re
import sys
from functools import lru_cache
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
MD_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
# docstring cross-links like "docs/ARCHITECTURE.md" or
# "docs/ARCHITECTURE.md#failure-handling" inside python sources
PY_DOC_REF = re.compile(
    r"\bdocs/[A-Za-z0-9_.-]+\.md(?:#[A-Za-z0-9_-]+)?")
SKIP_SCHEMES = ("http://", "https://", "mailto:")
MD_HEADING = re.compile(r"^#{1,6}\s+(.+?)\s*$", re.MULTILINE)


def _slugify(heading: str) -> str:
    """GitHub-style heading slug: drop markdown markers and punctuation,
    lowercase, spaces -> hyphens."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


@lru_cache(maxsize=None)
def heading_anchors(md_path: str) -> frozenset:
    """All anchor slugs a markdown file exposes (duplicate headings get
    GitHub's ``-1``/``-2`` suffixes)."""
    seen: dict = {}
    out = set()
    for m in MD_HEADING.finditer(Path(md_path).read_text(encoding="utf-8")):
        slug = _slugify(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")
    return frozenset(out)


def iter_files(suffix: str):
    for p in sorted(ROOT.rglob(f"*{suffix}")):
        if any(part.startswith(".") or part in ("experiments", "build")
               for part in p.relative_to(ROOT).parts[:-1]):
            continue
        yield p


def _check_anchor(src: Path, target: str, resolved: Path,
                  anchor: str, errors: list) -> None:
    if resolved.suffix != ".md":
        return                        # only markdown targets have headings
    if anchor not in heading_anchors(str(resolved)):
        errors.append(f"{src.relative_to(ROOT)}: broken anchor "
                      f"-> {target} (no heading slugs to {anchor!r} in "
                      f"{resolved.relative_to(ROOT)})")


def check_markdown() -> list:
    errors = []
    for md in iter_files(".md"):
        for m in MD_LINK.finditer(md.read_text(encoding="utf-8")):
            target = m.group(1)
            if target.startswith(SKIP_SCHEMES):
                continue
            path, _, anchor = target.partition("#")
            resolved = (md.parent / path).resolve() if path else md
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
                continue
            if anchor:
                _check_anchor(md, target, resolved, anchor, errors)
    return errors


def check_docstring_refs() -> list:
    errors = []
    for py in iter_files(".py"):
        for m in PY_DOC_REF.finditer(py.read_text(encoding="utf-8")):
            target = m.group(0)
            path, _, anchor = target.partition("#")
            resolved = ROOT / path
            if not resolved.exists():
                errors.append(f"{py.relative_to(ROOT)}: dangling doc "
                              f"reference -> {target}")
                continue
            if anchor:
                _check_anchor(py, target, resolved, anchor, errors)
    return errors


def main() -> int:
    errors = check_markdown() + check_docstring_refs()
    for e in errors:
        print(f"BROKEN: {e}")
    if errors:
        print(f"{len(errors)} broken doc link(s)")
        return 1
    print("doc links OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
