#!/usr/bin/env python
"""Fail CI on broken intra-repo documentation links.

Scans every tracked ``*.md`` file for markdown links/images and verifies
that relative targets exist on disk (anchors are stripped; external
``http(s):``/``mailto:`` targets are skipped).  Also verifies the
``docs/...`` path references that module docstrings use as cross-links.

Run:  python tools/check_doc_links.py  (from the repo root or anywhere)
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
MD_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
# docstring cross-links like "docs/ARCHITECTURE.md" or
# "see docs/ARCHITECTURE.md (...)" inside python sources
PY_DOC_REF = re.compile(r"\bdocs/[A-Za-z0-9_.-]+\.md\b")
SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")


def iter_files(suffix: str):
    for p in sorted(ROOT.rglob(f"*{suffix}")):
        if any(part.startswith(".") or part in ("experiments", "build")
               for part in p.relative_to(ROOT).parts[:-1]):
            continue
        yield p


def check_markdown() -> list:
    errors = []
    for md in iter_files(".md"):
        for m in MD_LINK.finditer(md.read_text(encoding="utf-8")):
            target = m.group(1)
            if target.startswith(SKIP_SCHEMES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
    return errors


def check_docstring_refs() -> list:
    errors = []
    for py in iter_files(".py"):
        for m in PY_DOC_REF.finditer(py.read_text(encoding="utf-8")):
            if not (ROOT / m.group(0)).exists():
                errors.append(f"{py.relative_to(ROOT)}: dangling doc "
                              f"reference -> {m.group(0)}")
    return errors


def main() -> int:
    errors = check_markdown() + check_docstring_refs()
    for e in errors:
        print(f"BROKEN: {e}")
    if errors:
        print(f"{len(errors)} broken doc link(s)")
        return 1
    print("doc links OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
